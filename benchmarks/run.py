"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus derived metrics per
experiment).  Fast by construction: the simulator benches are analytical;
the JAX benches use small shapes; the roofline report reads the cached
dry-run artifacts in ``artifacts/dryrun`` when present.

    PYTHONPATH=src python -m benchmarks.run [--only fig10,roofline]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np


def _artifacts() -> Path:
    """The artifacts/ output directory, created on demand — every bench
    that writes files goes through this (CI uploads warn, not silently
    skip, when a gate produced nothing)."""
    out = Path("artifacts")
    out.mkdir(parents=True, exist_ok=True)
    return out


def _time(fn, iters=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")


# --------------------------------------------------------------------------
# Fig. 2 — strategy sweep for Transformer-17B on the 2D mesh
# --------------------------------------------------------------------------

def bench_fig2():
    from repro.core.simulator import Simulator
    from repro.core.workloads import fig2_strategies, transformer
    sim = Simulator("baseline")
    rows = []

    def run():
        rows.clear()
        for st in fig2_strategies():
            w = transformer("T17B", 78, 4256, 1024, st, "stationary",
                            token_samples=False)
            br = sim.run(w)
            rows.append((str(st), br.compute / w.minibatch,
                         (br.total - br.compute) / w.minibatch))
    us = _time(run)
    emit("fig2_strategy_sweep", us, f"strategies={len(rows)}")
    for name, comp, comm in rows:
        emit(f"fig2[{name}]", 0.0,
             f"comp_ms_per_sample={comp*1e3:.3f};comm_ms_per_sample={comm*1e3:.3f}")


# --------------------------------------------------------------------------
# Fig. 4 — mesh I/O broadcast hotspot
# --------------------------------------------------------------------------

def bench_fig4():
    from repro.core.meshnet import MeshFabric

    def run():
        out = []
        for n in (4, 5, 8, 16, 32):
            m = MeshFabric(rows=n, cols=n)
            out.append((n, m.io_hotspot_load(), m.io_linerate_factor()))
        return out
    us = _time(run)
    emit("fig4_io_hotspot", us, "")
    for n, load, factor in run():
        emit(f"fig4[mesh{n}x{n}]", 0.0,
             f"hotspot_load={load}P;linerate_factor={factor:.3f}")
    m = MeshFabric()
    emit("fig4[paper_5x4]", 0.0,
         f"hotspot={m.io_hotspot_load()}x128GBps=1152GBps;"
         f"factor={m.io_linerate_factor():.3f} (paper: 0.65)")


# --------------------------------------------------------------------------
# Fig. 9 — communication microbenchmarks per 3D-parallelism phase
# --------------------------------------------------------------------------

def bench_fig9():
    """Reports *utilized NPU injection bandwidth* = traffic/time — the
    paper's Fig. 9 metric.  Expected (Sec. VIII): wafer AR baseline
    1500 GB/s, FRED-A 1875, FRED-B 1500 (half traffic), FRED-C/D 3000;
    strided DP: baseline 750, FRED-A/B 375, FRED-C/D 3000."""
    from repro.core.fabric import CONFIGS, FredFabric
    from repro.core.flows import (endpoint_traffic_bytes,
                                  innetwork_traffic_bytes)
    from repro.core.meshnet import MeshFabric
    mesh = MeshFabric()
    D = 128e6  # 128 MB collective

    cases = {
        "MP20_wafer_AR": ("all_reduce", list(range(20)), 1),
        "MP2_local_AR": ("all_reduce", [0, 1], 10),
        "DP5_strided_AR": ("all_reduce", [0, 4, 8, 12, 16], 4),
    }
    emit("fig9_microbench",
         _time(lambda: mesh.collective_time("all_reduce",
                                            list(range(20)), D)), "")
    for name, (kind, group, conc) in cases.items():
        n = len(group)
        tb = mesh.collective_time(kind, group, D)
        tr_ep = endpoint_traffic_bytes(kind, n, D)
        tr_in = innetwork_traffic_bytes(kind, n, D)
        row = [f"baseline={tr_ep/tb/1e9:.0f}GBps_util"]
        for cfg in ("FRED-A", "FRED-B", "FRED-C", "FRED-D"):
            fab = FredFabric(CONFIGS[cfg])
            tf_ = fab.collective_time(kind, group, D, concurrent_groups=conc)
            tr = tr_in if CONFIGS[cfg].in_network else tr_ep
            row.append(f"{cfg}={tr/tf_/1e9:.0f}GBps_util")
        emit(f"fig9[{name}]", 0.0, ";".join(row))


# --------------------------------------------------------------------------
# Fig. 10 — end-to-end training time (the headline result)
# --------------------------------------------------------------------------

def bench_fig10():
    from repro.core.calibrate import (CALIBRATED, PAPER_SPEEDUPS,
                                      simulate_speedups)
    args = (CALIBRATED["compute_efficiency"],
            CALIBRATED["mesh_step_overhead"],
            CALIBRATED["fred_step_overhead"])
    us = _time(lambda: simulate_speedups(*args), iters=2)
    sp = simulate_speedups(*args)
    emit("fig10_end2end", us, "")
    for w, row in sp.items():
        tgt = PAPER_SPEEDUPS[w]
        emit(f"fig10[{w}]", 0.0,
             f"FRED-C={row['FRED-C']:.2f}(paper {tgt['FRED-C']});"
             f"FRED-D={row['FRED-D']:.2f}(paper {tgt['FRED-D']})")


# --------------------------------------------------------------------------
# sweep — strategy/topology co-exploration (core/sweep.py)
# --------------------------------------------------------------------------

def bench_sweep():
    from repro.core.sweep import transformer_17b_sweep, to_csv_rows

    out_box = []

    def run():
        out_box[:] = [transformer_17b_sweep(n) for n in (16, 20, 32)]
    us = _time(run, iters=1)
    sweeps = out_box
    total = sum(len(s) for s in sweeps)
    emit("sweep_t17b", us, f"points={total};wafers=16,20,32")
    for n, res in zip((16, 20, 32), sweeps):
        par = sorted((r for r in res if r.pareto),
                     key=lambda r: (r.fabric, r.time_per_sample))
        emit(f"sweep[{n}npus]", 0.0,
             f"points={len(res)};pareto={len(par)}")
        best = {}
        for r in par:
            best.setdefault(r.fabric, r)
        for fab, r in sorted(best.items()):
            emit(f"sweep[{n}npus|{fab}]", 0.0,
                 f"best={r.strategy};shape={r.shape[0]}x{r.shape[1]};"
                 f"t_per_sample_us={r.time_per_sample*1e6:.2f}")
    # multi-wafer scale-out: 20-NPU wafers, clusters of 1 and 2 wafers
    cl_box = []

    def run_cluster():
        cl_box[:] = [transformer_17b_sweep(20, max_wafers=2)]
    us_cl = _time(run_cluster, iters=1)
    cluster = cl_box[0]
    cross = [r for r in cluster if r.pareto and r.strategy.wafers > 1]
    emit("sweep_t17b_cluster", us_cl,
         f"points={len(cluster)};wafers<=2;cross_wafer_pareto={len(cross)}")
    for r in sorted(cross, key=lambda r: (r.fabric, r.time_per_sample))[:3]:
        emit(f"sweep[cluster|{r.fabric}]", 0.0,
             f"best={r.strategy};shape={r.shape[0]}x{r.shape[1]}x"
             f"{r.n_wafers}w;t_per_sample_us={r.time_per_sample*1e6:.2f};"
             f"dp_intra_ms={r.breakdown.dp_intra*1e3:.3f};"
             f"dp_inter_ms={r.breakdown.dp_inter*1e3:.3f}")
    out = _artifacts()
    from repro.core.sweep import CSV_HEADER
    # the cluster sweep's n_wafers=1 slice duplicates the 20-NPU rows
    # above (with pareto flags computed over a different population), so
    # only its multi-wafer points are appended
    rows = [CSV_HEADER] + to_csv_rows(
        [r for s in sweeps for r in s] +
        [r for r in cluster if r.n_wafers > 1])
    (out / "sweep_t17b.csv").write_text("\n".join(rows) + "\n")
    emit("sweep[csv]", 0.0, f"artifacts/sweep_t17b.csv rows={len(rows)-1}")


# --------------------------------------------------------------------------
# sweepperf — scalar vs batched sweep-engine wall time (BENCH_sweep.json)
# --------------------------------------------------------------------------

# (case name, sweep kwargs): the perf-trajectory grid.  64-NPU and
# 64-NPU × 4-wafer run both engines; the exhaustive 512-NPU sweep (8×64 /
# 16×32-class FRED shapes) is batched-only unless --sweepperf-full — the
# scalar oracle needs tens of seconds there, which is the point.
SWEEPPERF_CASES = (
    ("64npu", dict(n_npus=64, max_wafers=1)),
    ("64npu_4wafer", dict(n_npus=64, max_wafers=4)),
    ("512npu", dict(n_npus=512, max_wafers=1)),
)


def bench_sweepperf(full: bool = False, budget_64: float = 0.0,
                    budget_512: float = 0.0):
    """Wall-time + points/sec for the sweep engines; writes
    BENCH_sweep.json (schema: benchmarks/README.md) so future PRs have a
    perf baseline to regress against.  ``budget_*`` (seconds, 0 = off)
    turn the bench into a CI gate on the batched engine."""
    from repro.core import batch_engine  # noqa: F401 — preload numpy path
    from repro.core.sweep import transformer_17b_sweep

    transformer_17b_sweep(20)            # warm imports/allocators once
    cases = {}
    for name, kw in SWEEPPERF_CASES:
        engines = ["batched", "scalar"]
        if name == "512npu" and not full:
            engines = ["batched"]
        entry = {"n_npus": kw["n_npus"], "max_wafers": kw["max_wafers"],
                 "points": 0, "engines": {}}
        for eng in engines:
            if eng == "scalar":
                iters = 1 if kw["n_npus"] >= 512 else 3
            else:
                iters = 5
            best = None
            for _ in range(iters):
                t0 = time.perf_counter()
                res = transformer_17b_sweep(engine=eng, **kw)
                dt = time.perf_counter() - t0
                best = dt if best is None else min(best, dt)
            entry["points"] = len(res)
            entry["engines"][eng] = {
                "seconds": round(best, 4),
                "points_per_sec": round(len(res) / best, 1)}
            emit(f"sweepperf[{name}|{eng}]", best * 1e6,
                 f"points={len(res)};points_per_sec={len(res)/best:.0f}")
        if "scalar" in entry["engines"]:
            sp = (entry["engines"]["scalar"]["seconds"] /
                  entry["engines"]["batched"]["seconds"])
            entry["speedup_batched_vs_scalar"] = round(sp, 2)
            emit(f"sweepperf[{name}|speedup]", 0.0,
                 f"batched_vs_scalar={sp:.1f}x")
        cases[name] = entry
    payload = {"schema": 1, "workload": "Transformer-17B",
               "timing": "best-of-N wall time per engine", "cases": cases}
    Path("BENCH_sweep.json").write_text(
        json.dumps(payload, indent=2) + "\n")
    emit("sweepperf[json]", 0.0, f"BENCH_sweep.json cases={len(cases)}")
    errors = []
    b64 = cases["64npu"]["engines"]["batched"]["seconds"]
    b512 = cases["512npu"]["engines"]["batched"]["seconds"]
    if budget_64 and b64 > budget_64:
        errors.append(f"64npu batched sweep {b64:.3f}s > {budget_64}s budget")
    if budget_512 and b512 > budget_512:
        errors.append(f"512npu batched sweep {b512:.3f}s > "
                      f"{budget_512}s budget")
    if errors:
        for e in errors:
            print(f"sweepperf[BUDGET],0.0,{e}", file=sys.stderr)
        sys.exit("sweepperf: batched sweep blew the CI wall-time budget — "
                 "a perf regression in core/batch_engine.py or core/"
                 "sweep.py (compare against the committed BENCH_sweep.json)")


# --------------------------------------------------------------------------
# hiersweep — hierarchical scale-out × inter-wafer topology gate
# --------------------------------------------------------------------------

# 64-NPU wafers × clusters of ≤4 wafers × every inter-wafer topology ×
# ≤2 hierarchy levels (flat ring-of-wafers and rack×pod stackings) — the
# ISSUE 5 acceptance sweep.
HIERSWEEP_KW = dict(n_npus=64, max_wafers=4, max_levels=2, n_layers=78)


def bench_hiersweep(budget: float = 0.0):
    """Times the batched (fabric × shape × wafers × hierarchy × topology
    × strategy) sweep, verifies it bit-identical to the scalar oracle,
    and writes the decision CSV (Pareto front + best strategy per
    (fabric, topology, hierarchy) slice) to
    ``artifacts/hiersweep_decisions.csv``.  ``budget`` (seconds, 0 = off)
    turns the batched wall time into a CI gate, mirroring sweepperf."""
    from repro.core.cluster import INTER_TOPOLOGIES
    from repro.core.sweep import CSV_HEADER, sweep, to_csv_rows, \
        transformer_17b

    kw = dict(HIERSWEEP_KW, inter_topologies=INTER_TOPOLOGIES)
    sweep(transformer_17b, 20, n_layers=78)      # warm imports/allocators
    best = None
    for _ in range(3):
        t0 = time.perf_counter()
        res = sweep(transformer_17b, engine="batched", **kw)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    emit("hiersweep[batched]", best * 1e6,
         f"points={len(res)};points_per_sec={len(res)/best:.0f}")
    # batched-vs-scalar parity gate: the vectorized per-level inter
    # collectives must reproduce the scalar decomposition bit-for-bit
    t0 = time.perf_counter()
    oracle = sweep(transformer_17b, engine="scalar", **kw)
    emit("hiersweep[scalar]", (time.perf_counter() - t0) * 1e6,
         f"points={len(oracle)}")
    mismatches = 0
    for ra, rb in zip(oracle, res):
        if ((ra.fabric, ra.shape, ra.strategy, ra.n_wafers, ra.hierarchy,
             ra.inter_topology) !=
            (rb.fabric, rb.shape, rb.strategy, rb.n_wafers, rb.hierarchy,
             rb.inter_topology) or
                ra.breakdown.as_dict() != rb.breakdown.as_dict() or
                ra.breakdown.dp_levels != rb.breakdown.dp_levels or
                ra.pareto != rb.pareto):
            mismatches += 1
    if len(oracle) != len(res) or mismatches:
        print(f"hiersweep[PARITY],0.0,{mismatches} mismatching points "
              f"(scalar {len(oracle)} vs batched {len(res)})",
              file=sys.stderr)
        sys.exit("hiersweep: batched engine diverged from the scalar "
                 "oracle on the hierarchy/topology axes — a bit-parity "
                 "regression in core/batch_engine.py")
    emit("hiersweep[parity]", 0.0,
         f"batched==scalar over {len(res)} points")
    # decision CSV: the Pareto front plus the fastest strategy of every
    # (fabric, inter topology, hierarchy) slice — small enough to ride
    # as a CI artifact, complete enough to diff topology decisions
    chosen = {}
    for r in res:
        key = (r.fabric, r.inter_topology, r.hierarchy)
        if key not in chosen or r.time_per_sample < \
                chosen[key].time_per_sample:
            chosen[key] = r
    rows = [r for r in res if r.pareto]
    rows += [r for r in chosen.values() if not r.pareto]
    path = _artifacts() / "hiersweep_decisions.csv"
    path.write_text("\n".join([CSV_HEADER] + to_csv_rows(rows)) + "\n")
    emit("hiersweep[csv]", 0.0, f"{path} rows={len(rows)}")
    for (fab, topo, hier), r in sorted(chosen.items()):
        if topo:
            emit(f"hiersweep[{fab}|{topo}|{'x'.join(map(str, hier))}]",
                 0.0,
                 f"best={r.strategy};shape={r.shape[0]}x{r.shape[1]};"
                 f"t_per_sample_us={r.time_per_sample*1e6:.3f};"
                 f"dp_levels_ms="
                 f"{'/'.join(f'{x*1e3:.3f}' for x in r.breakdown.dp_levels)}")
    if budget and best > budget:
        print(f"hiersweep[BUDGET],0.0,batched {best:.3f}s > {budget}s",
              file=sys.stderr)
        sys.exit("hiersweep: batched hierarchy sweep blew the CI "
                 "wall-time budget — a perf regression in "
                 "core/batch_engine.py or core/sweep.py")


# --------------------------------------------------------------------------
# faultsweep — defect masks: degraded sweeps + yield studies gate
# --------------------------------------------------------------------------

# the yield-study grid: the paper's Transformer-17B on its 20-NPU wafer
# plus one registry model under the policy's frozen defaults, each over
# 32 sampled masks at the 2% dead-NPU rate.  CI diffs the defect-free
# winner, survival tally, and every degraded fallback decision against
# tests/goldens/faultsweep.json.
FAULTSWEEP_N_MASKS = 32
FAULTSWEEP_DEAD_RATE = 0.02


def bench_faultsweep(budget: float = 0.0, goldens: str = ""):
    """Times the batched degraded sweep, verifies it bit-identical to the
    scalar oracle under a non-trivial defect mask, runs the yield studies,
    and writes the per-mask outcome CSV to
    ``artifacts/faultsweep_yield.csv``.  ``budget`` (seconds, 0 = off)
    gates the combined wall time; ``goldens`` diffs the degraded
    auto-strategy decisions, mirroring the autostrategy gate."""
    from repro.core.defects import sample_mask
    from repro.core.sweep import sweep, transformer_17b
    from repro.core.yield_study import (YIELD_CSV_HEADER, model_yield_study,
                                        yield_csv_rows, yield_study)

    sweep(transformer_17b, 20, n_layers=78)      # warm imports/allocators
    mask = sample_mask(20, dead_npu_rate=0.1, dead_link_rate=0.05, seed=1,
                       mesh_shape=(5, 4))
    assert not mask.is_empty, "faultsweep parity mask drew no defects"
    kw = dict(n_layers=78, min_utilization=0.5, defects=mask)
    best = None
    for _ in range(3):
        t0 = time.perf_counter()
        res = sweep(transformer_17b, 20, engine="batched", **kw)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    emit("faultsweep[batched]", best * 1e6,
         f"points={len(res)};dead_npus={len(mask.dead_npus)};"
         f"dead_links={len(mask.dead_links)}")
    # batched-vs-scalar parity under the mask: compacted placements, mesh
    # detours and uplink factors must reproduce the scalar walk bit-for-bit
    t0 = time.perf_counter()
    oracle = sweep(transformer_17b, 20, engine="scalar", **kw)
    emit("faultsweep[scalar]", (time.perf_counter() - t0) * 1e6,
         f"points={len(oracle)}")
    mismatches = sum(
        1 for ra, rb in zip(oracle, res)
        if (ra.fabric, ra.shape, ra.strategy) !=
           (rb.fabric, rb.shape, rb.strategy)
        or ra.breakdown.as_dict() != rb.breakdown.as_dict()
        or (ra.pareto, ra.degraded_time_s) != (rb.pareto, rb.degraded_time_s))
    if len(oracle) != len(res) or mismatches:
        print(f"faultsweep[PARITY],0.0,{mismatches} mismatching points "
              f"(scalar {len(oracle)} vs batched {len(res)})",
              file=sys.stderr)
        sys.exit("faultsweep: batched engine diverged from the scalar "
                 "oracle under a defect mask — a bit-parity regression "
                 "in core/batch_engine.py")
    emit("faultsweep[parity]", 0.0,
         f"batched==scalar over {len(res)} masked points")
    # yield studies: Transformer-17B + one registry model, 32 masks @ 2%
    t0 = time.perf_counter()
    ykw = dict(n_masks=FAULTSWEEP_N_MASKS, dead_npu_rate=FAULTSWEEP_DEAD_RATE)
    studies = {
        "transformer-17b": yield_study(transformer_17b, 20, n_layers=78,
                                       **ykw),
        "llama3.2-1b/train_4k": model_yield_study("llama3.2-1b", **ykw),
    }
    t_yield = time.perf_counter() - t0
    rows = [YIELD_CSV_HEADER]
    for name, rep in studies.items():
        w = rep.winner
        emit(f"faultsweep[{name}]", rep.study_seconds * 1e6,
             f"winner={w.strategy}@{w.fabric};"
             f"survival={rep.n_survived}/{rep.n_masks};"
             f"fallbacks={rep.n_fallback};"
             f"mean_slowdown={rep.mean_slowdown:.3f}x")
        rows += yield_csv_rows(rep)
    path = _artifacts() / "faultsweep_yield.csv"
    path.write_text("\n".join(rows) + "\n")
    emit("faultsweep[csv]", 0.0, f"{path} rows={len(rows)-1}")
    if goldens:
        want = json.loads(Path(goldens).read_text())
        got = {name: rep.golden() for name, rep in studies.items()}
        errors = [f"{k}: {got.get(k)} != golden {want.get(k)}"
                  for k in sorted(set(want) | set(got))
                  if got.get(k) != want.get(k)]
        if errors:
            for e in errors:
                print(f"faultsweep[GOLDEN-DIFF],0.0,{e}", file=sys.stderr)
            print(json.dumps(got, indent=1, sort_keys=True),
                  file=sys.stderr)
            sys.exit("faultsweep: degraded auto-strategy decisions "
                     f"diverge from {goldens} — if the cost-model change "
                     "is intended, regenerate the goldens from the JSON "
                     "printed above")
        emit("faultsweep[goldens]", 0.0, f"match {goldens}")
    t_total = best + t_yield
    if budget and t_total > budget:
        print(f"faultsweep[BUDGET],0.0,{t_total:.3f}s > {budget}s",
              file=sys.stderr)
        sys.exit("faultsweep: masked sweep + yield studies blew the CI "
                 "wall-time budget — a perf regression in the defect "
                 "paths of core/batch_engine.py, core/sweep.py or "
                 "core/yield_study.py")


# --------------------------------------------------------------------------
# autostrategy — sweep-driven (mp, dp, pp, wafers) decisions per model
# --------------------------------------------------------------------------

# 2-3 registry models spanning the decision space: small-dense (DP-heavy),
# MoE mid (MP-heavy), and the 480B streaming fallback.  CI diffs these
# against tests/goldens/autostrategy.json.
AUTOSTRATEGY_ARCHS = ("llama3.2-1b", "mixtral-8x7b", "arctic-480b")


def bench_autostrategy(goldens: str = ""):
    from repro.core.autostrategy import (DECISION_CSV_HEADER, check_goldens,
                                         decision_csv_rows, decision_table)
    box = []

    def run():
        box[:] = decision_table(AUTOSTRATEGY_ARCHS)
    us = _time(run, iters=1)
    decisions = box
    emit("autostrategy_decisions", us, f"models={len(decisions)}")
    for d in decisions:
        emit(f"autostrategy[{d.arch}]", 0.0,
             f"chosen={d.strategy}@{d.fabric};"
             f"shape={d.wafer_shape[0]}x{d.wafer_shape[1]};"
             f"execution={d.execution};"
             f"mem_GiB={d.memory_bytes_per_npu/2**30:.2f};"
             f"t_per_sample_us={d.time_per_sample_s*1e6:.3f};"
             f"candidates={d.n_candidates};infeasible={d.n_infeasible};"
             f"dominated={d.n_dominated}")
    path = _artifacts() / "autostrategy_decisions.csv"
    path.write_text("\n".join([DECISION_CSV_HEADER] +
                              decision_csv_rows(decisions)) + "\n")
    emit("autostrategy[csv]", 0.0, f"{path} rows={len(decisions)}")
    if goldens:
        errors = check_goldens(decisions, goldens)
        if errors:
            for e in errors:
                print(f"autostrategy[GOLDEN-DIFF],0.0,{e}", file=sys.stderr)
            sys.exit("autostrategy: chosen strategies diverge from "
                     f"{goldens} — if the cost-model change is intended, "
                     "regenerate the goldens (tests/test_autostrategy.py "
                     "prints the new table)")
        emit("autostrategy[goldens]", 0.0, f"match {goldens}")


# --------------------------------------------------------------------------
# epsweep — expert/sequence-parallel axes + overlap-aware cost model gate
# --------------------------------------------------------------------------

# the 7-axis parity grid: a real MoE workload (mixtral-8x7b, whose
# Workload carries a2a_bytes_per_sample_layer/expert_param_fraction) over
# (fabric × shape × wafers × strategy × ep × sp), re-run per overlap
# fraction — every point exercises the All-to-All kernels and the
# exposed-comm chain on both engines.
EPSWEEP_ARCH = "mixtral-8x7b"
EPSWEEP_OVERLAPS = (0.0, 0.3)


def bench_epsweep(budget: float = 0.0, goldens: str = ""):
    """The expert-parallel CI gate: batched↔scalar bit parity over every
    (ep × sp × all_to_all × overlap) sweep point, then the MoE
    auto-strategy decisions (both :data:`repro.core.autostrategy
    .MOE_ARCHS` entries must choose ``ep > 1``) diffed against
    ``tests/goldens/epsweep.json``; writes
    ``artifacts/epsweep_decisions.csv``.  ``budget`` (seconds, 0 = off)
    gates the batched wall time across all overlap fractions."""
    from repro.core.autostrategy import (DECISION_CSV_HEADER, EP_SWEEP_KW,
                                         MOE_ARCHS, check_goldens,
                                         decision_csv_rows, decision_table)
    from repro.configs.registry import get_config
    from repro.core.sweep import sweep
    from repro.core.workloads import (MemoryModel, adapter_n_layers,
                                      from_model_config)
    from repro.models.config import SHAPES_BY_NAME

    cfg = get_config(EPSWEEP_ARCH)
    shape = SHAPES_BY_NAME["train_4k"]

    def wl(st):
        return from_model_config(cfg, shape, st, execution="stationary")

    kw = dict(n_layers=adapter_n_layers(cfg), max_wafers=2,
              memory=MemoryModel(), **EP_SWEEP_KW)
    sweep(wl, 64, **kw)                        # warm imports/allocators
    t_batched = 0.0
    for overlap in EPSWEEP_OVERLAPS:
        okw = dict(kw, comm_overlap_fraction=overlap)
        best = None
        for _ in range(3):
            t0 = time.perf_counter()
            res = sweep(wl, 64, engine="batched", **okw)
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        t_batched += best
        n_ep = sum(1 for r in res if r.strategy.ep > 1)
        n_sp = sum(1 for r in res if r.strategy.sp > 1)
        emit(f"epsweep[batched|overlap={overlap}]", best * 1e6,
             f"points={len(res)};ep_points={n_ep};sp_points={n_sp};"
             f"points_per_sec={len(res)/best:.0f}")
        # batched-vs-scalar parity: the A2A structure memo, the masked
        # EP groups and the exposed-comm chain must reproduce the scalar
        # walk bit-for-bit on every new axis
        t0 = time.perf_counter()
        oracle = sweep(wl, 64, engine="scalar", **okw)
        emit(f"epsweep[scalar|overlap={overlap}]",
             (time.perf_counter() - t0) * 1e6, f"points={len(oracle)}")
        mismatches = sum(
            1 for ra, rb in zip(oracle, res)
            if (ra.fabric, ra.shape, ra.strategy, ra.n_wafers) !=
               (rb.fabric, rb.shape, rb.strategy, rb.n_wafers)
            or ra.breakdown.as_dict() != rb.breakdown.as_dict()
            or ra.breakdown.dp_levels != rb.breakdown.dp_levels
            or (ra.pareto, ra.feasible) != (rb.pareto, rb.feasible))
        if len(oracle) != len(res) or mismatches:
            print(f"epsweep[PARITY],0.0,{mismatches} mismatching points "
                  f"at overlap={overlap} (scalar {len(oracle)} vs "
                  f"batched {len(res)})", file=sys.stderr)
            sys.exit("epsweep: batched engine diverged from the scalar "
                     "oracle on the ep/sp/overlap axes — a bit-parity "
                     "regression in core/batch_engine.py")
        emit(f"epsweep[parity|overlap={overlap}]", 0.0,
             f"batched==scalar over {len(res)} points")
    # MoE decisions: the whole point of the new axes — both MoE registry
    # entries must elect expert parallelism once it is searchable
    box = []

    def run():
        box[:] = decision_table(MOE_ARCHS, **EP_SWEEP_KW)
    us = _time(run, iters=1)
    decisions = box
    emit("epsweep_decisions", us, f"models={len(decisions)}")
    for d in decisions:
        emit(f"epsweep[{d.arch}]", 0.0,
             f"chosen={d.strategy}@{d.fabric};execution={d.execution};"
             f"ep={d.ep};sp={d.sp};"
             f"mem_GiB={d.memory_bytes_per_npu/2**30:.2f};"
             f"t_per_sample_us={d.time_per_sample_s*1e6:.3f}")
    path = _artifacts() / "epsweep_decisions.csv"
    path.write_text("\n".join([DECISION_CSV_HEADER] +
                              decision_csv_rows(decisions)) + "\n")
    emit("epsweep[csv]", 0.0, f"{path} rows={len(decisions)}")
    no_ep = [d.arch for d in decisions if d.ep <= 1]
    if no_ep:
        print(f"epsweep[EP-REGRESSION],0.0,{','.join(no_ep)} chose ep=1",
              file=sys.stderr)
        sys.exit("epsweep: MoE model(s) no longer elect expert "
                 "parallelism — the EP cost/memory model regressed "
                 "(simulator EP phase, ep_share, or the sweep axes)")
    if goldens:
        errors = check_goldens(decisions, goldens)
        if errors:
            for e in errors:
                print(f"epsweep[GOLDEN-DIFF],0.0,{e}", file=sys.stderr)
            sys.exit("epsweep: MoE decisions diverge from "
                     f"{goldens} — if the cost-model change is intended, "
                     "regenerate with tests/gen_epsweep_golden.py")
        emit("epsweep[goldens]", 0.0, f"match {goldens}")
    if budget and t_batched > budget:
        print(f"epsweep[BUDGET],0.0,batched {t_batched:.3f}s > {budget}s",
              file=sys.stderr)
        sys.exit("epsweep: batched ep/sp sweep blew the CI wall-time "
                 "budget — a perf regression in the A2A/overlap kernels "
                 "of core/batch_engine.py or core/sweep.py")


# --------------------------------------------------------------------------
# lifetimesweep — MTBF-driven goodput vs healthy-time decisions gate
# --------------------------------------------------------------------------

LIFETIME_CSV_HEADER = (
    "arch,shape,objective,fabric,shape_a,shape_b,mp,dp,pp,ep,sp,wafers,"
    "execution,flip,mtbf_npu_hours,time_per_sample_s,"
    "goodput_samples_per_s,ckpt_write_s,ckpt_interval_s,useful_fraction,"
    "survives_mission")


def bench_lifetimesweep(budget: float = 0.0, goldens: str = ""):
    """The lifetime-goodput CI gate: every registry arch decided twice —
    healthy time vs lifetime goodput at :data:`repro.core.autostrategy
    .LIFETIME_MTBF_NPU_HOURS` — with two invariants always checked: at
    least one arch must *flip* its strategy under failures (otherwise
    the objective is vacuous), and at ``mtbf = ∞`` the goodput decision
    must be identical to the time decision for every arch (the bit-
    identity that keeps the pre-lifetime goldens byte-stable).
    ``--goldens`` diffs the pairs against tests/goldens/
    lifetimesweep.json; writes ``artifacts/lifetimesweep_decisions.csv``.
    ``budget`` (seconds, 0 = off) gates the total decision wall time."""
    from repro.core.autostrategy import (LIFETIME_ARCHS, LIFETIME_SWEEP_KW,
                                         LIFETIME_MTBF_NPU_HOURS,
                                         _strategy_signature,
                                         check_lifetime_goldens,
                                         decision_table,
                                         lifetime_decision_pairs,
                                         lifetime_golden)
    box = []

    def run():
        box[:] = lifetime_decision_pairs()
    us = _time(run, iters=1)
    pairs = box
    emit("lifetimesweep_decisions", us,
         f"models={len(pairs)};mtbf_npu_h={LIFETIME_MTBF_NPU_HOURS}")
    rows = []
    n_flips = 0
    for t, g in pairs:
        flip = lifetime_golden((t, g))["flip"]
        n_flips += flip
        emit(f"lifetimesweep[{t.arch}]", 0.0,
             f"time={t.strategy}@{t.fabric};goodput={g.strategy}@"
             f"{g.fabric};flip={int(flip)};"
             f"goodput_samples_per_s={g.goodput_samples_per_s:.1f};"
             f"useful_fraction={g.useful_fraction:.4f};"
             f"ckpt_write_s={g.ckpt_write_s:.3f};"
             f"ckpt_interval_s={g.ckpt_interval_s:.1f};"
             f"survives={int(g.survives_mission)}")
        for d in (t, g):
            rows.append(
                f"{d.arch},{d.shape},{d.objective},{d.fabric},"
                f"{d.wafer_shape[0]},{d.wafer_shape[1]},"
                f"{d.mp},{d.dp},{d.pp},{d.ep},{d.sp},{d.wafers},"
                f"{d.execution},{int(flip)},{d.mtbf_npu_hours:.9g},"
                f"{d.time_per_sample_s:.9g},"
                f"{d.goodput_samples_per_s:.9g},{d.ckpt_write_s:.9g},"
                f"{d.ckpt_interval_s:.9g},{d.useful_fraction:.9g},"
                f"{int(d.survives_mission)}")
    path = _artifacts() / "lifetimesweep_decisions.csv"
    path.write_text("\n".join([LIFETIME_CSV_HEADER] + rows) + "\n")
    emit("lifetimesweep[csv]", 0.0, f"{path} rows={len(rows)}")
    if not n_flips:
        print("lifetimesweep[FLIP-REGRESSION],0.0,no arch flips between "
              "time and goodput objectives", file=sys.stderr)
        sys.exit("lifetimesweep: the goodput objective no longer flips "
                 "any registry decision at the pinned MTBF — the "
                 "failure/degradation model regressed (core/lifetime.py "
                 "chain, elastic reachability, or checkpoint costs)")
    emit("lifetimesweep[flips]", 0.0,
         f"{n_flips}/{len(pairs)} archs flip at "
         f"mtbf={LIFETIME_MTBF_NPU_HOURS}h/NPU")
    # mtbf=∞ bit-identity: goodput must reduce to the time objective
    inf_d = decision_table(LIFETIME_ARCHS, objective="goodput",
                           **LIFETIME_SWEEP_KW)
    drift = [t.arch for (t, _g), i in zip(pairs, inf_d)
             if _strategy_signature(t) != _strategy_signature(i)]
    if drift:
        print(f"lifetimesweep[INF-IDENTITY],0.0,{','.join(drift)} differ "
              f"at mtbf=inf", file=sys.stderr)
        sys.exit("lifetimesweep: goodput at mtbf=∞ is no longer "
                 "bit-identical to the time objective — the never-fails "
                 "degeneracy in core/lifetime.py broke, which also "
                 "endangers the pre-lifetime goldens")
    emit("lifetimesweep[inf-identity]", 0.0,
         f"goodput@mtbf=inf == time for all {len(inf_d)} archs")
    if goldens:
        errors = check_lifetime_goldens(pairs, goldens)
        if errors:
            for e in errors:
                print(f"lifetimesweep[GOLDEN-DIFF],0.0,{e}",
                      file=sys.stderr)
            sys.exit("lifetimesweep: decisions diverge from "
                     f"{goldens} — if the cost-model change is intended, "
                     "regenerate with tests/gen_lifetime_golden.py")
        emit("lifetimesweep[goldens]", 0.0, f"match {goldens}")
    wall_s = us / 1e6
    if budget and wall_s > budget:
        print(f"lifetimesweep[BUDGET],0.0,decisions {wall_s:.3f}s > "
              f"{budget}s", file=sys.stderr)
        sys.exit("lifetimesweep: the time+goodput decision table blew "
                 "the CI wall-time budget — a perf regression in the "
                 "degradation-chain fallback sweeps or their cache "
                 "(core/lifetime.py)")


# --------------------------------------------------------------------------
# servesweep — serving-cell decisions gate (ISSUE 10)
# --------------------------------------------------------------------------

def bench_servesweep(budget: float = 0.0, goldens: str = ""):
    """The serving-cell CI gate: :data:`repro.core.autostrategy
    .SERVESWEEP_ARCHS` decided under the pinned production objective
    (1M concurrent users / 60 s think time / 200 ms p99 TTFT — qwen3-32b
    under it is the ROADMAP's north-star wafer-count question), with two
    invariants always checked: the M/D/c closed form must agree with the
    seeded discrete-event traffic simulator to <1 % on mean TTFT at
    every decision's operating point (the lifetime.py
    estimate-vs-simulate contract), and disaggregated serving must never
    lose raw capacity to co-located at equal hardware (the by-
    construction superset property).  ``--goldens`` diffs the decisions
    against tests/goldens/servesweep.json; writes
    ``artifacts/servesweep_decisions.csv``.  ``budget`` (seconds,
    0 = off) gates the decision wall time."""
    from repro.configs.registry import get_config
    from repro.core.autostrategy import (SERVESWEEP_ARCHS, SERVE_OBJECTIVE,
                                         SERVE_SWEEP_KW,
                                         check_serving_goldens,
                                         serving_decision_table)
    from repro.core.serving import (RequestProfile, serving_candidates,
                                    serving_csv_rows, simulate_traffic)
    box = []

    def run():
        box[:] = serving_decision_table()
    us = _time(run, iters=1)
    decisions = box
    emit("servesweep_decisions", us,
         f"models={len(decisions)};"
         f"users={SERVE_OBJECTIVE.concurrent_users};"
         f"p99_slo_ms={SERVE_OBJECTIVE.target_p99_ms}")
    # invariant 1: closed-form queueing vs the seeded traffic simulator,
    # <1% on mean TTFT at each decision's per-cell operating rate
    for d in decisions:
        cand = d.cell
        lam_op = d.arrival_rate_rps / d.n_cells
        slots, occupancy_s = cand.queue_shape()
        est_s = cand.base_ttft_s + cand.ttft_stats(lam_op).mean_wait_s
        sim = simulate_traffic(lam_op, occupancy_s, slots,
                               base_latency_s=cand.base_ttft_s, seed=0)
        rel = abs(est_s - sim["mean_ttft_s"]) / sim["mean_ttft_s"]
        emit(f"servesweep[{d.arch}]", 0.0,
             f"placement={d.placement};wafers={d.total_wafers};"
             f"cells={d.n_cells};ttft_p99_ms={d.ttft_p99_ms:.4g};"
             f"est_mean_ttft_ms={est_s * 1e3:.4g};"
             f"sim_mean_ttft_ms={sim['mean_ttft_s'] * 1e3:.4g};"
             f"agreement={rel * 100:.3f}%")
        if rel >= 0.01:
            print(f"servesweep[EST-VS-SIM],0.0,{d.arch}: closed form "
                  f"{est_s:.6g}s vs DES {sim['mean_ttft_s']:.6g}s "
                  f"({rel * 100:.2f}% > 1%)", file=sys.stderr)
            sys.exit("servesweep: the M/D/c queueing approximation no "
                     "longer agrees with the seeded traffic simulator "
                     "to <1% — the closed form and the DES in "
                     "core/serving.py have drifted apart")
    # invariant 2: disaggregated ≥ co-located raw capacity per wafer
    # count (checked on the north-star arch's full candidate set)
    cfg = get_config("qwen3-32b")
    profile = RequestProfile(prompt_tokens=SERVE_OBJECTIVE.prompt_tokens,
                             output_tokens=SERVE_OBJECTIVE.output_tokens)
    cands = serving_candidates(cfg, profile, **SERVE_SWEEP_KW)
    for w in range(1, SERVE_SWEEP_KW["max_wafers"] + 1):
        coloc = max(c.capacity_rps for c in cands
                    if c.placement == "colocated" and c.wafers == w)
        disagg = max(c.capacity_rps for c in cands
                     if c.placement == "disaggregated" and c.wafers == w)
        if disagg < coloc:
            print(f"servesweep[DISAGG-CAPACITY],0.0,w={w}: disaggregated "
                  f"{disagg:.4g} rps < colocated {coloc:.4g} rps",
                  file=sys.stderr)
            sys.exit("servesweep: disaggregated serving lost raw "
                     "capacity to co-located at equal hardware — the "
                     "per-phase optima in core/serving.py no longer "
                     "cover the shared-config space")
        emit(f"servesweep[disagg>=coloc w={w}]", 0.0,
             f"disagg={disagg:.6g}rps;coloc={coloc:.6g}rps")
    rows = serving_csv_rows(decisions)
    path = _artifacts() / "servesweep_decisions.csv"
    path.write_text("\n".join(rows) + "\n")
    emit("servesweep[csv]", 0.0, f"{path} rows={len(rows) - 1}")
    if goldens:
        errors = check_serving_goldens(decisions, goldens)
        if errors:
            for e in errors:
                print(f"servesweep[GOLDEN-DIFF],0.0,{e}", file=sys.stderr)
            sys.exit("servesweep: decisions diverge from "
                     f"{goldens} — if the cost-model change is intended, "
                     "regenerate with tests/gen_servesweep_golden.py")
        emit("servesweep[goldens]", 0.0, f"match {goldens}")
    wall_s = us / 1e6
    if budget and wall_s > budget:
        print(f"servesweep[BUDGET],0.0,decisions {wall_s:.3f}s > "
              f"{budget}s", file=sys.stderr)
        sys.exit("servesweep: the serving decision table blew the CI "
                 "wall-time budget — a perf regression in the candidate "
                 "enumeration or the SLO-capacity search "
                 "(core/serving.py)")


# --------------------------------------------------------------------------
# Table III — FRED switch HW overhead
# --------------------------------------------------------------------------

def bench_table3():
    from repro.core.switch import FredSwitch, hw_overhead
    us = _time(lambda: hw_overhead(FredSwitch.build(12, 3)))
    emit("table3_hw_overhead", us, "")
    total_area = total_power = 0.0
    for ports, count, paper_area in ((12, 15, 685), (11, 10, 678), (10, 10, 814)):
        o = hw_overhead(FredSwitch.build(ports, 3))
        total_area += count * o["area_mm2"]
        total_power += count * o["power_w"]
        emit(f"table3[FRED3({ports})x{count}]", 0.0,
             f"area={o['area_mm2']:.0f}mm2(paper {paper_area});"
             f"power={o['power_w']:.2f}W;microswitches={o['microswitches']}")
    emit("table3[total]", 0.0,
         f"area={total_area:.0f}mm2(paper 25195);power={total_power + 58:.0f}W"
         f"(paper 146.73, incl. 58W wiring)")
    # shape-derived accounting (core/fabric.py): logical switch inventory
    from repro.core.fabric import CONFIGS, FredFabric
    for shape in ((5, 4), (8, 4), (4, 8)):
        fab = FredFabric(CONFIGS["FRED-C"], n_groups=shape[0],
                         group_size=shape[1])
        acc = fab.hw_accounting()
        inv = ";".join(f"FRED3({p})x{c}" for _l, p, c in
                       fab.switch_inventory())
        emit(f"table3[derived {shape[0]}x{shape[1]}]", 0.0,
             f"{inv};area={acc['area_mm2']:.0f}mm2;power={acc['power_w']:.1f}W")


# --------------------------------------------------------------------------
# routing: conflict rates vs m (Fig. 7 related)
# --------------------------------------------------------------------------

def bench_routing():
    import random
    from repro.core.flows import all_reduce
    from repro.core.routing import routable
    from repro.core.switch import FredSwitch
    rng = random.Random(0)
    P = 16

    def random_flows():
        ports = list(range(P))
        rng.shuffle(ports)
        flows, i = [], 0
        while i + 2 <= P:
            k = rng.choice([2, 3, 4])
            flows.append(all_reduce(sorted(ports[i:i + k]))[0][0])
            i += k
        return flows

    trials = [random_flows() for _ in range(200)]
    out = {}
    for m in (2, 3):
        sw = FredSwitch.build(P, m)
        t0 = time.perf_counter()
        ok = sum(routable(sw, f) for f in trials)
        dt = (time.perf_counter() - t0) / len(trials) * 1e6
        out[m] = (ok, dt)
    emit("routing_conflicts", out[3][1],
         f"m2_routable={out[2][0]}/200;m3_routable={out[3][0]}/200")


# --------------------------------------------------------------------------
# JAX collectives: hierarchical vs flat wire bytes (FRED-B analogy)
# --------------------------------------------------------------------------

def bench_collectives():
    import jax
    from repro.parallel.compress import compression_ratio
    n_data, n_pod, D = 16, 2, 64 * 2**20
    flat_cross_pod = 2 * (n_pod * n_data - 1) / (n_pod * n_data) * D
    hier_cross_pod = 2 * (n_pod - 1) / n_pod * (D / n_data)
    comp_cross_pod = hier_cross_pod * compression_ratio(D // n_data)
    emit("collective_bytes", 0.0,
         f"flat_crosspod_MB={flat_cross_pod/2**20:.1f};"
         f"hier_crosspod_MB={hier_cross_pod/2**20:.1f};"
         f"compressed_crosspod_MB={comp_cross_pod/2**20:.1f};"
         f"reduction={flat_cross_pod/comp_cross_pod:.0f}x")


# --------------------------------------------------------------------------
# roofline report (reads cached dry-run artifacts)
# --------------------------------------------------------------------------

def bench_roofline():
    art = Path("artifacts/dryrun")
    if not art.exists():
        emit("roofline", 0.0, "no artifacts (run repro.launch.dryrun first)")
        return
    rows = []
    for p in sorted(art.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        rows.append((r["arch"], r["shape"], r["mesh"], rf))
    emit("roofline_report", 0.0, f"cells={len(rows)}")
    for arch, shape, mesh, rf in rows:
        emit(f"roofline[{arch}|{shape}|{mesh}]", 0.0,
             f"compute_s={rf['compute_s']:.4f};memory_s={rf['memory_s']:.4f};"
             f"collective_s={rf['collective_s']:.4f};dominant={rf['dominant']};"
             f"fraction={rf['roofline_fraction']:.4f};"
             f"useful={rf['useful_flops_ratio']:.3f}")


# --------------------------------------------------------------------------
# staticcheck — the repro.analysis invariant gate (ISSUE 7)
# --------------------------------------------------------------------------

def bench_staticcheck():
    """Run the five static invariant checkers (layering / parity / units /
    determinism / deprecation) as a benchmark-harness gate.

    An alias for ``python -m repro.analysis --check`` so the suite rides
    the existing gate plumbing (``--only staticcheck``); writes the JSON
    findings report to ``artifacts/analysis_report.json`` and exits
    non-zero on any non-baselined finding, like the golden gates do.
    """
    from repro.analysis.__main__ import DEFAULT_BASELINE
    from repro.analysis.__main__ import main as analysis_main
    report = _artifacts() / "analysis_report.json"
    t0 = time.perf_counter()
    rc = analysis_main(["--check", "--baseline", DEFAULT_BASELINE,
                        "--json", str(report)])
    us = (time.perf_counter() - t0) * 1e6
    counts = json.loads(report.read_text())["counts_by_rule"]
    emit("staticcheck", us,
         ";".join(f"{r}={n}" for r, n in sorted(counts.items())))
    emit("staticcheck[report]", 0.0, str(report))
    if rc:
        sys.exit("staticcheck: new invariant findings (see above) — fix "
                 "them, suppress with `# repro: ignore[RULE]`, or (last "
                 "resort) regen tests/goldens/analysis_baseline.json")


BENCHES = {
    "fig2": bench_fig2,
    "fig4": bench_fig4,
    "fig9": bench_fig9,
    "fig10": bench_fig10,
    "sweep": bench_sweep,
    "sweepperf": bench_sweepperf,
    "hiersweep": bench_hiersweep,
    "faultsweep": bench_faultsweep,
    "autostrategy": bench_autostrategy,
    "epsweep": bench_epsweep,
    "lifetimesweep": bench_lifetimesweep,
    "servesweep": bench_servesweep,
    "table3": bench_table3,
    "routing": bench_routing,
    "collectives": bench_collectives,
    "roofline": bench_roofline,
    "staticcheck": bench_staticcheck,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default="")
    ap.add_argument("--goldens", type=str, default="",
                    help="autostrategy/faultsweep/epsweep: diff chosen "
                         "strategies against this JSON (tests/goldens/"
                         "<bench>.json); exit non-zero on mismatch")
    ap.add_argument("--sweepperf-full", action="store_true",
                    help="sweepperf only: also time the scalar engine on "
                         "the 512-NPU sweep (tens of seconds — the "
                         "committed BENCH_sweep.json is generated with "
                         "this flag)")
    ap.add_argument("--sweepperf-budget-64", type=float, default=0.0,
                    help="sweepperf only: fail if the 64-NPU batched "
                         "sweep exceeds this many seconds (CI gate)")
    ap.add_argument("--sweepperf-budget-512", type=float, default=0.0,
                    help="sweepperf only: fail if the 512-NPU batched "
                         "sweep exceeds this many seconds (CI gate)")
    ap.add_argument("--faultsweep-budget", type=float, default=0.0,
                    help="faultsweep only: fail if the masked batched "
                         "sweep plus the 32-mask yield studies exceed "
                         "this many seconds (CI gate; parity vs the "
                         "scalar oracle under the mask is always "
                         "checked; --goldens also diffs the degraded "
                         "decisions against tests/goldens/"
                         "faultsweep.json)")
    ap.add_argument("--epsweep-budget", type=float, default=0.0,
                    help="epsweep only: fail if the batched MoE ep/sp "
                         "sweep (summed over the overlap fractions) "
                         "exceeds this many seconds (CI gate; parity vs "
                         "the scalar oracle and the ep>1 MoE decisions "
                         "are always checked; --goldens diffs against "
                         "tests/goldens/epsweep.json)")
    ap.add_argument("--lifetimesweep-budget", type=float, default=0.0,
                    help="lifetimesweep only: fail if the time+goodput "
                         "decision table exceeds this many seconds (CI "
                         "gate; the ≥1-flip and mtbf=∞ bit-identity "
                         "invariants are always checked; --goldens diffs "
                         "against tests/goldens/lifetimesweep.json)")
    ap.add_argument("--servesweep-budget", type=float, default=0.0,
                    help="servesweep only: fail if the serving-cell "
                         "decision table exceeds this many seconds (CI "
                         "gate; the <1% estimate-vs-simulate agreement "
                         "and the disaggregated≥co-located capacity "
                         "invariants are always checked; --goldens diffs "
                         "against tests/goldens/servesweep.json)")
    ap.add_argument("--hiersweep-budget", type=float, default=0.0,
                    help="hiersweep only: fail if the batched 64-NPU × "
                         "4-wafer × {ring,fully_connected,switch} × "
                         "≤2-level sweep exceeds this many seconds "
                         "(CI gate; parity vs the scalar oracle is "
                         "always checked)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        sys.exit(f"unknown benchmark(s) {unknown}; "
                 f"choose from {', '.join(BENCHES)}")
    print("name,us_per_call,derived")
    for n in names:
        if n == "autostrategy":
            bench_autostrategy(goldens=args.goldens)
        elif n == "sweepperf":
            bench_sweepperf(full=args.sweepperf_full,
                            budget_64=args.sweepperf_budget_64,
                            budget_512=args.sweepperf_budget_512)
        elif n == "hiersweep":
            bench_hiersweep(budget=args.hiersweep_budget)
        elif n == "faultsweep":
            bench_faultsweep(budget=args.faultsweep_budget,
                             goldens=args.goldens)
        elif n == "epsweep":
            bench_epsweep(budget=args.epsweep_budget,
                          goldens=args.goldens)
        elif n == "lifetimesweep":
            bench_lifetimesweep(budget=args.lifetimesweep_budget,
                                goldens=args.goldens)
        elif n == "servesweep":
            bench_servesweep(budget=args.servesweep_budget,
                             goldens=args.goldens)
        else:
            BENCHES[n]()


if __name__ == "__main__":
    main()
