"""Generate markdown tables for EXPERIMENTS.md from artifacts."""
import json
from pathlib import Path

def fmt(v, n=4):
    return f"{v:.{n}f}"

def roofline_table(mesh):
    rows = []
    for p in sorted(Path("artifacts/dryrun").glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "skipped":
            rows.append((r["arch"], r["shape"], "skip", "-", "-", "-", "-", "-", "-", "-"))
            continue
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        mem = r["memory_per_device"]["total_bytes"] / 2**30
        rows.append((r["arch"], r["shape"], rf["dominant"],
                     fmt(rf["compute_s"]), fmt(rf["memory_s"]), fmt(rf["collective_s"]),
                     fmt(rf["roofline_fraction"]), fmt(rf["useful_flops_ratio"], 3),
                     f"{mem:.2f}", "✓" if mem <= 16 else "✗"))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (order.get(r[1], 9), r[0]))
    out = ["| arch | shape | dominant | compute_s | memory_s | collective_s | roofline frac | useful | GiB/dev | ≤16GiB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)

def perf_table():
    out = ["| cell | variant | compute_s | memory_s | collective_s | frac | GiB/dev |",
           "|---|---|---|---|---|---|---|"]
    # baselines first
    for cell in ("qwen3-32b__train_4k", "mixtral-8x7b__train_4k", "arctic-480b__train_4k"):
        b = json.loads((Path("artifacts/dryrun") / f"{cell}__single.json").read_text())
        rf = b["roofline"]
        out.append(f"| {cell} | **baseline (paper-faithful)** | {fmt(rf['compute_s'],2)} | "
                   f"{fmt(rf['memory_s'],2)} | {fmt(rf['collective_s'],2)} | "
                   f"{fmt(rf['roofline_fraction'])} | "
                   f"{b['memory_per_device']['total_bytes']/2**30:.1f} |")
        for p in sorted(Path("artifacts/perf").glob(f"{cell}__v*.json")):
            r = json.loads(p.read_text())
            if r.get("status") != "ok":
                out.append(f"| {cell} | {p.stem.split('__')[-1]} | error | | | | |")
                continue
            rf = r["roofline"]
            out.append(f"| {cell} | {r['variant']} | {fmt(rf['compute_s'],2)} | "
                       f"{fmt(rf['memory_s'],2)} | {fmt(rf['collective_s'],2)} | "
                       f"{fmt(rf['roofline_fraction'])} | "
                       f"{r['memory_per_device']['total_bytes']/2**30:.1f} |")
    return "\n".join(out)

if __name__ == "__main__":
    import sys
    which = sys.argv[1]
    if which == "single":
        print(roofline_table("single"))
    elif which == "multi":
        print(roofline_table("multi"))
    else:
        print(perf_table())
